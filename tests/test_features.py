"""Paper feature extraction: host path vs device (jnp) path + invariants."""
import numpy as np

from repro.core.features import (FEATURE_NAMES, extract_features,
                                 extract_features_jnp)
from repro.sparse.csr import permute_symmetric


def test_feature_count_and_names(small_suite):
    assert len(FEATURE_NAMES) == 12  # Table 3
    f = extract_features(small_suite[0])
    assert f.shape == (12,)
    assert np.isfinite(f).all()


def test_jnp_matches_numpy(small_suite):
    for m in small_suite[:3]:
        host = extract_features(m)
        dev = np.asarray(extract_features_jnp(m.to_dense()))
        np.testing.assert_allclose(dev, host, rtol=1e-4)


def test_permutation_invariants(small_suite, rng):
    """dimension/nnz/degree-multiset survive symmetric permutation;
    bandwidth & profile generally change."""
    m = small_suite[1]
    perm = rng.permutation(m.n)
    mp = permute_symmetric(m, perm)
    f0, f1 = extract_features(m), extract_features(mp)
    for name in ["dimension", "nnz", "nnz_ratio", "nnz_max", "nnz_min",
                 "nnz_avg", "degree_max", "degree_min", "degree_avg"]:
        i = FEATURE_NAMES.index(name)
        np.testing.assert_allclose(f0[i], f1[i], rtol=1e-9, err_msg=name)
