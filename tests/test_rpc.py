"""RPC front-end: framing, round trips, separate-process clients.

The server under test wraps a real dispatch pipeline (micro-batcher +
build workers) over a tiny in-memory-cached engine, bound to an ephemeral
localhost port. The acceptance-path test talks to it from a *separate
client process* — cold request builds a plan, warm request is served from
cache — which is exactly what the CI smoke (``repro.launch.rpc --smoke``)
re-runs on the 4-virtual-device leg.
"""
import os
import socket
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core.features import FEATURE_NAMES, extract_features_batch
from repro.core.ml import RandomForestClassifier
from repro.core.scaling import SCALERS
from repro.core.selector import ReorderSelector
from repro.engine import EngineConfig, SolverEngine
from repro.launch.rpc import (PlanRPCClient, PlanRPCServer, RPCError,
                              matrix_from_wire, matrix_to_wire, recv_frame,
                              send_frame)
from repro.sparse.dataset import generate_suite

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def mats():
    return list(generate_suite(count=8, seed=3, size_scale=0.25))


@pytest.fixture(scope="module")
def engine(mats):
    feats = extract_features_batch(mats)
    labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
              / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
    # resolve the scaler through the registry at fixture time: the engine
    # fingerprints by registry name_of(), and test_engine.py's reload-
    # tolerance test swaps the registered class mid-suite — a class
    # imported at collection time would no longer resolve
    scaler = SCALERS["standard"]().fit(feats)
    rf = RandomForestClassifier(n_estimators=8).fit(
        scaler.transform(feats), labels)
    sel = ReorderSelector(rf, scaler, ["amd", "rcm"])
    return SolverEngine(EngineConfig(cache_dir=None, batch_size=4,
                                     max_wait_ms=2.0), selector=sel)


@pytest.fixture()
def server(engine):
    srv = engine.serve(rpc=True, port=0)
    yield srv
    srv.close()


# ---------------------------------------------------------------------------
# framing + wire format
# ---------------------------------------------------------------------------

def test_frame_round_trip():
    a, b = socket.socketpair()
    try:
        payload = {"op": "x", "arr": np.arange(7, dtype=np.int32)}
        send_frame(a, payload)
        got = recv_frame(b)
        assert got["op"] == "x"
        np.testing.assert_array_equal(got["arr"], payload["arr"])
    finally:
        a.close()
        b.close()


def test_matrix_wire_round_trip(mats):
    m = mats[0]
    back = matrix_from_wire(matrix_to_wire(m))
    assert back.n == m.n and back.nnz == m.nnz
    np.testing.assert_array_equal(back.indptr, m.indptr)
    np.testing.assert_array_equal(back.indices, m.indices)
    np.testing.assert_array_equal(back.data, m.data)


# ---------------------------------------------------------------------------
# in-process client round trips
# ---------------------------------------------------------------------------

def test_ping_plan_select_stats(server, mats):
    with PlanRPCClient(server.host, server.port) as c:
        assert c.ping()["ok"]
        plan, cold_ms = c.plan_with_timing(mats[0])
        assert plan.algorithm in ("amd", "rcm")
        assert sorted(plan.perm.tolist()) == list(range(mats[0].n))
        plan2, _warm_ms = c.plan_with_timing(mats[0])
        assert np.array_equal(plan.perm, plan2.perm)
        names = c.select(mats[:4])
        assert all(n in ("amd", "rcm") for n in names)
        s = c.stats()
        assert s["requests"] >= 2 and s["warm_hits"] >= 1


def test_plan_batch_op(server, mats):
    with PlanRPCClient(server.host, server.port) as c:
        plans = c.plan_batch(mats)
        assert len(plans) == len(mats)
        for m, p in zip(mats, plans):
            assert sorted(p.perm.tolist()) == list(range(m.n))


def test_unknown_op_and_malformed(server):
    with PlanRPCClient(server.host, server.port) as c:
        with pytest.raises(RPCError, match="unknown op"):
            c._call("definitely-not-an-op")
        send_frame(c._sock, ["not", "a", "dict"])
        resp = recv_frame(c._sock)
        assert not resp["ok"] and "malformed" in resp["error"]
        # connection survives a bad request
        assert c.ping()["ok"]


def test_concurrent_clients_batch_together(server, mats):
    """Several client connections in flight at once all resolve — their
    misses fan into one micro-batching queue."""
    errs = []

    def one(i):
        try:
            with PlanRPCClient(server.host, server.port) as c:
                p = c.plan(mats[i % len(mats)])
                assert p.algorithm in ("amd", "rcm")
        except Exception as exc:  # pragma: no cover - diagnostic
            errs.append(exc)

    ts = [threading.Thread(target=one, args=(i,)) for i in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(120)
    assert not errs


def test_shutdown_op_acks_before_teardown(engine):
    """The shutdown response must reach the client — teardown is deferred
    until the ack frame is on the wire, so shutdown() never sees a reset."""
    srv = engine.serve(rpc=True, port=0)
    with PlanRPCClient(srv.host, srv.port, timeout=30) as c:
        c.shutdown()  # raises if the server resets before answering
    srv._accept_thread.join(30)
    assert srv._closed.is_set()
    srv.close()  # idempotent with the op-triggered close


def test_garbage_frames_do_not_kill_server(server, mats):
    """Non-protocol peers (scanners, HTTP probes, corrupt frames) get
    dropped; the server keeps serving real clients."""
    import struct

    # oversized length prefix
    s1 = socket.create_connection((server.host, server.port), timeout=10)
    s1.sendall(struct.pack(">I", (1 << 30) + 1) + b"xx")
    # valid length, garbage (unpicklable) payload
    s2 = socket.create_connection((server.host, server.port), timeout=10)
    s2.sendall(struct.pack(">I", 4) + b"\x00\x01\x02\x03")
    for s in (s1, s2):  # both connections get closed server-side
        try:
            assert s.recv(1) == b""  # clean EOF …
        except OSError:
            pass  # … or RST (unread bytes pending at close) — both fine
        s.close()
    with PlanRPCClient(server.host, server.port) as c:  # still serving
        assert c.ping()["ok"]
        assert c.plan(mats[0]).algorithm in ("amd", "rcm")


def test_close_idempotent_and_drops_live_clients(engine):
    srv = engine.serve(rpc=True, port=0)
    c = PlanRPCClient(srv.host, srv.port, timeout=10)
    assert c.ping()["ok"]
    srv.close()
    srv.close()  # second close is a no-op
    # the established connection was shut down server-side: the next call
    # sees EOF (ConnectionError) or a reset (OSError) — never a hang
    with pytest.raises((ConnectionError, OSError)):
        c.ping()
    c.close()


# ---------------------------------------------------------------------------
# the acceptance path: a separate client PROCESS, cold + warm
# ---------------------------------------------------------------------------

def test_cold_and_warm_from_separate_process(server, mats):
    child = textwrap.dedent("""
        import sys
        import numpy as np
        from repro.launch.rpc import PlanRPCClient
        from repro.sparse.dataset import grid2d
        port = int(sys.argv[1])
        m = grid2d(8, 8, "rpc-proc")
        with PlanRPCClient("127.0.0.1", port) as c:
            cold, _ = c.plan_with_timing(m)
            warm, _ = c.plan_with_timing(m)
            stats = c.stats()
        assert cold.algorithm == warm.algorithm
        assert np.array_equal(cold.perm, warm.perm)
        assert stats["warm_hits"] >= 1, stats
        print("PROC-RPC-OK", cold.algorithm)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", child, str(server.port)],
                       capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "PROC-RPC-OK" in r.stdout
