"""RPC front-end: framing, round trips, separate-process clients.

The server under test wraps a real dispatch pipeline (micro-batcher +
build workers) over a tiny in-memory-cached engine, bound to an ephemeral
localhost port. The acceptance-path test talks to it from a *separate
client process* — cold request builds a plan, warm request is served from
cache — which is exactly what the CI smoke (``repro.launch.rpc --smoke``)
re-runs on the 4-virtual-device leg.
"""
import os
import socket
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core.features import FEATURE_NAMES, extract_features_batch
from repro.core.ml import RandomForestClassifier
from repro.core.scaling import SCALERS
from repro.core.selector import ReorderSelector
from repro.engine import EngineConfig, SolverEngine
from repro.core.reqctx import SERVING_ERRORS, DeadlineExceeded
from repro.launch.rpc import (PlanRPCClient, PlanRPCServer, RPCError,
                              error_frame, matrix_from_wire, matrix_to_wire,
                              raise_from_frame, recv_frame, send_frame)
from repro.sparse.dataset import generate_suite, grid2d

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def mats():
    return list(generate_suite(count=8, seed=3, size_scale=0.25))


@pytest.fixture(scope="module")
def engine(mats):
    feats = extract_features_batch(mats)
    labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
              / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
    # resolve the scaler through the registry at fixture time: the engine
    # fingerprints by registry name_of(), and test_engine.py's reload-
    # tolerance test swaps the registered class mid-suite — a class
    # imported at collection time would no longer resolve
    scaler = SCALERS["standard"]().fit(feats)
    rf = RandomForestClassifier(n_estimators=8).fit(
        scaler.transform(feats), labels)
    sel = ReorderSelector(rf, scaler, ["amd", "rcm"])
    return SolverEngine(EngineConfig(cache_dir=None, batch_size=4,
                                     max_wait_ms=2.0), selector=sel)


@pytest.fixture()
def server(engine):
    srv = engine.serve(rpc=True, port=0)
    yield srv
    srv.close()


# ---------------------------------------------------------------------------
# framing + wire format
# ---------------------------------------------------------------------------

def test_frame_round_trip():
    a, b = socket.socketpair()
    try:
        payload = {"op": "x", "arr": np.arange(7, dtype=np.int32)}
        send_frame(a, payload)
        got = recv_frame(b)
        assert got["op"] == "x"
        np.testing.assert_array_equal(got["arr"], payload["arr"])
    finally:
        a.close()
        b.close()


def test_matrix_wire_round_trip(mats):
    m = mats[0]
    back = matrix_from_wire(matrix_to_wire(m))
    assert back.n == m.n and back.nnz == m.nnz
    np.testing.assert_array_equal(back.indptr, m.indptr)
    np.testing.assert_array_equal(back.indices, m.indices)
    np.testing.assert_array_equal(back.data, m.data)


# ---------------------------------------------------------------------------
# in-process client round trips
# ---------------------------------------------------------------------------

def test_ping_plan_select_stats(server, mats):
    with PlanRPCClient(server.host, server.port) as c:
        assert c.ping()["ok"]
        plan, cold_ms = c.plan_with_timing(mats[0])
        assert plan.algorithm in ("amd", "rcm")
        assert sorted(plan.perm.tolist()) == list(range(mats[0].n))
        plan2, _warm_ms = c.plan_with_timing(mats[0])
        assert np.array_equal(plan.perm, plan2.perm)
        names = c.select(mats[:4])
        assert all(n in ("amd", "rcm") for n in names)
        s = c.stats()
        assert s["requests"] >= 2 and s["warm_hits"] >= 1


def test_plan_batch_op(server, mats):
    with PlanRPCClient(server.host, server.port) as c:
        plans = c.plan_batch(mats)
        assert len(plans) == len(mats)
        for m, p in zip(mats, plans):
            assert sorted(p.perm.tolist()) == list(range(m.n))


def test_unknown_op_and_malformed(server):
    with PlanRPCClient(server.host, server.port) as c:
        with pytest.raises(RPCError, match="unknown op"):
            c._call("definitely-not-an-op")
        send_frame(c._sock, ["not", "a", "dict"])
        resp = recv_frame(c._sock)
        assert not resp["ok"] and "malformed" in resp["error"]
        # connection survives a bad request
        assert c.ping()["ok"]


def test_concurrent_clients_batch_together(server, mats):
    """Several client connections in flight at once all resolve — their
    misses fan into one micro-batching queue."""
    errs = []

    def one(i):
        try:
            with PlanRPCClient(server.host, server.port) as c:
                p = c.plan(mats[i % len(mats)])
                assert p.algorithm in ("amd", "rcm")
        except Exception as exc:  # pragma: no cover - diagnostic
            errs.append(exc)

    ts = [threading.Thread(target=one, args=(i,)) for i in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(120)
    assert not errs


def test_shutdown_op_acks_before_teardown(engine):
    """The shutdown response must reach the client — teardown is deferred
    until the ack frame is on the wire, so shutdown() never sees a reset."""
    srv = engine.serve(rpc=True, port=0)
    with PlanRPCClient(srv.host, srv.port, timeout=30) as c:
        c.shutdown()  # raises if the server resets before answering
    srv._accept_thread.join(30)
    assert srv._closed.is_set()
    srv.close()  # idempotent with the op-triggered close


def test_garbage_frames_do_not_kill_server(server, mats):
    """Non-protocol peers (scanners, corrupt frames) get a structured
    error frame explaining why, then the connection is dropped; the
    server keeps serving real clients."""
    import struct

    # oversized length prefix (only the prefix — no trailing bytes, so
    # the server-side close is clean and the error frame is readable)
    s1 = socket.create_connection((server.host, server.port), timeout=10)
    s1.sendall(struct.pack(">I", (1 << 30) + 1))
    # valid length, garbage (unpicklable) payload
    s2 = socket.create_connection((server.host, server.port), timeout=10)
    s2.sendall(struct.pack(">I", 4) + b"\x00\x01\x02\x03")
    for s in (s1, s2):
        try:
            resp = recv_frame(s)
        except (ConnectionError, OSError, RPCError):
            pass  # reset before the frame landed — dropped is dropped
        else:
            assert not resp["ok"] and "malformed frame" in resp["error"]
            # …then the connection is closed: there is no frame boundary
            # to resync to after a corrupt frame
            try:
                assert s.recv(1) == b""
            except OSError:
                pass
        s.close()
    with PlanRPCClient(server.host, server.port) as c:  # still serving
        assert c.ping()["ok"]
        assert c.plan(mats[0]).algorithm in ("amd", "rcm")


def test_close_idempotent_and_drops_live_clients(engine):
    srv = engine.serve(rpc=True, port=0)
    c = PlanRPCClient(srv.host, srv.port, timeout=10)
    assert c.ping()["ok"]
    srv.close()
    srv.close()  # second close is a no-op
    # the established connection was shut down server-side: the next call
    # sees EOF (ConnectionError) or a reset (OSError) — never a hang
    with pytest.raises((ConnectionError, OSError)):
        c.ping()
    c.close()


# ---------------------------------------------------------------------------
# the acceptance path: a separate client PROCESS, cold + warm
# ---------------------------------------------------------------------------

def test_cold_and_warm_from_separate_process(server, mats):
    child = textwrap.dedent("""
        import sys
        import numpy as np
        from repro.launch.rpc import PlanRPCClient
        from repro.sparse.dataset import grid2d
        port = int(sys.argv[1])
        m = grid2d(8, 8, "rpc-proc")
        with PlanRPCClient("127.0.0.1", port) as c:
            cold, _ = c.plan_with_timing(m)
            warm, _ = c.plan_with_timing(m)
            stats = c.stats()
        assert cold.algorithm == warm.algorithm
        assert np.array_equal(cold.perm, warm.perm)
        assert stats["warm_hits"] >= 1, stats
        print("PROC-RPC-OK", cold.algorithm)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", child, str(server.port)],
                       capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "PROC-RPC-OK" in r.stdout


# ---------------------------------------------------------------------------
# the RequestContext spine over the wire
# ---------------------------------------------------------------------------

def test_error_frames_round_trip_typed_errors():
    """Every typed serving error survives the wire by name; anything else
    degrades to an RPCError that still carries the structured fields."""
    for name, cls in SERVING_ERRORS.items():
        frame = error_frame(cls("boom"), op="plan", request_id="r1")
        assert frame["error_type"] == name
        assert frame["op"] == "plan" and frame["request_id"] == "r1"
        with pytest.raises(cls, match="boom"):
            raise_from_frame(frame)
    with pytest.raises(RPCError) as ei:
        raise_from_frame(error_frame(ValueError("nope"), op="plan",
                                     request_id="r2"))
    assert ei.value.error_type == "ValueError"
    assert ei.value.request_id == "r2"


def test_request_identity_and_spans_round_trip(server):
    cold = grid2d(12, 12, "wire-ident")  # structure no other test plans
    with PlanRPCClient(server.host, server.port) as c:
        resp = c.plan_detailed(cold, request_id="req-wire-42",
                               deadline_ms=60_000, priority=2)
        assert resp["ok"] and resp["request_id"] == "req-wire-42"
        # one context accumulated the whole cold path, stage by stage
        assert {"queue", "select", "build", "cache",
                "total"} <= set(resp["spans_ms"])
        assert resp["server_ms"] > 0
        warm = c.plan_detailed(cold)
        assert warm["request_id"].startswith("req-")  # server-minted
        assert set(warm["spans_ms"]) == {"cache", "total"}  # never queued


def test_deadline_shed_typed_over_wire(server):
    """A deadline below cold-path latency sheds with a typed error; the
    connection survives, and a warm hit succeeds even with zero budget."""
    cold = grid2d(13, 13, "wire-deadline")
    with PlanRPCClient(server.host, server.port) as c:
        with pytest.raises(DeadlineExceeded):
            c.plan(cold, deadline_ms=0)
        p = c.plan(cold)  # no deadline: builds fine on the same socket
        p2 = c.plan(cold, deadline_ms=0)  # warm: served despite the budget
        assert np.array_equal(p.perm, p2.perm)
        assert c.stats()["shed"] >= 1


def test_plan_batch_partial_errors(server, mats):
    cold = grid2d(14, 14, "wire-batch")
    with PlanRPCClient(server.host, server.port) as c:
        c.plan(mats[0])  # ensure one member is warm
        resp = c.plan_batch_detailed([mats[0], cold], deadline_ms=0)
        assert resp["ok"]
        assert resp["plans"][0] is not None  # warm member served
        assert resp["plans"][1] is None      # cold member shed
        err = resp["errors"][1]
        assert err["error_type"] == "DeadlineExceeded"
        assert err["request_id"] == resp["request_ids"][1]
        # the convenience wrapper re-raises the first typed error
        with pytest.raises(DeadlineExceeded):
            c.plan_batch([mats[0], cold], deadline_ms=0)


def test_metrics_consistent_across_client_processes(engine):
    """Fork-based consistency check: several client *processes* hammer one
    server concurrently (each RPC connection gets its own handler thread);
    afterwards the metrics registry must account for every request exactly
    — racing threads splitting or dropping counts would show up here."""
    from repro.core.plan_cache import matrix_fingerprint

    srv = engine.serve(rpc=True, port=0)
    try:
        srv.dispatcher.reset_stats()
        n_procs, n_mats = 3, 4
        # the module-scoped engine may have planned some of the child
        # suite already — only structures absent from the cache build
        child_mats = list(generate_suite(count=n_mats, seed=77,
                                         size_scale=0.25))
        expect_cold = sum(
            srv.dispatcher.cache.peek(matrix_fingerprint(m)) is None
            for m in child_mats)
        child = textwrap.dedent("""
            import sys
            from repro.launch.rpc import PlanRPCClient
            from repro.sparse.dataset import generate_suite, grid2d
            mats = list(generate_suite(count=4, seed=77, size_scale=0.25))
            with PlanRPCClient("127.0.0.1", int(sys.argv[1]),
                               timeout=120) as c:
                for m in mats:
                    assert c.plan(m).algorithm in ("amd", "rcm")
            print("CHILD-OK")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        procs = [subprocess.Popen([sys.executable, "-c", child,
                                   str(srv.port)],
                                  stdout=subprocess.PIPE,
                                  stderr=subprocess.PIPE, text=True, env=env)
                 for _ in range(n_procs)]
        for p in procs:
            out, err = p.communicate(timeout=420)
            assert p.returncode == 0, out + "\n" + err
            assert "CHILD-OK" in out
        with PlanRPCClient(srv.host, srv.port) as c:
            m = c.metrics()
            s = c.stats()
        total = n_procs * n_mats
        assert m["dispatch.requests"] == total
        assert m["dispatch.latency_s.count"] == total
        # every submit either hit or missed the memory tier — no request
        # vanished between the RPC threads and the cache counters
        assert m["cache.memory_hits"] + m["cache.misses"] == total
        # distinct cold structures are built exactly once (in-flight
        # dedup); already-cached ones are warm hits, not rebuilds
        assert s["plans_built"] == expect_cold
        assert m["rpc.requests"] >= total
        assert m["rpc.connections"] >= n_procs
    finally:
        srv.close()
