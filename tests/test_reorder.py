"""Reordering algorithms: permutation validity, quality properties,
and a SciPy RCM oracle comparison."""
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.sparse.csr import bandwidth, permute_symmetric
from repro.sparse.dataset import grid2d, permuted_banded, scalefree
from repro.sparse.reorder import REORDERINGS, get_reordering
from repro.sparse.symbolic import fill_in

ALGS = sorted(REORDERINGS)


@pytest.mark.parametrize("alg", ALGS)
def test_valid_permutation(alg, small_suite):
    for m in small_suite:
        perm = get_reordering(alg)(m)
        assert perm.shape == (m.n,)
        assert np.array_equal(np.sort(perm), np.arange(m.n)), alg


def test_rcm_recovers_band():
    rng = np.random.default_rng(1)
    m = permuted_banded(200, 3, 0.9, rng, "pb")
    bw_before = bandwidth(m)
    perm = get_reordering("rcm")(m)
    bw_after = bandwidth(permute_symmetric(m, perm))
    assert bw_after < bw_before / 4, (bw_before, bw_after)


def test_rcm_close_to_scipy_rcm():
    """Our RCM should land in the same bandwidth class as SciPy's."""
    m = grid2d(15, 15, "g")
    ours = bandwidth(permute_symmetric(m, get_reordering("rcm")(m)))
    s = sp.csr_matrix(m.to_dense())
    sp_perm = csgraph.reverse_cuthill_mckee(s, symmetric_mode=True)
    theirs = bandwidth(permute_symmetric(m, np.asarray(sp_perm, np.int64)))
    assert ours <= 2 * max(theirs, 1), (ours, theirs)


@pytest.mark.parametrize("alg", ["md", "amd", "qamd", "amf", "scotch"])
def test_fill_reducers_beat_natural_on_scalefree(alg):
    rng = np.random.default_rng(0)
    m = scalefree(150, 2, rng, "sf")
    f_nat = fill_in(m)
    perm = get_reordering(alg)(m)
    f_alg = fill_in(permute_symmetric(m, perm))
    assert f_alg < f_nat / 2, (alg, f_nat, f_alg)


def test_nd_beats_natural_on_grid():
    m = grid2d(20, 20, "g")
    f_nat = fill_in(m)
    f_nd = fill_in(permute_symmetric(m, get_reordering("nd")(m)))
    assert f_nd < f_nat, (f_nat, f_nd)


def test_md_exact_vs_amd_similar_quality():
    m = grid2d(12, 12, "g")
    f_md = fill_in(permute_symmetric(m, get_reordering("md")(m)))
    f_amd = fill_in(permute_symmetric(m, get_reordering("amd")(m)))
    # AMD's approximate degrees should stay within 2x of exact MD fill
    assert f_amd <= 2 * f_md + 50, (f_md, f_amd)
