"""Numeric solvers vs dense reference; multifrontal Pallas backend."""
import numpy as np
import pytest

from repro.sparse.multifrontal import multifrontal_cholesky, multifrontal_solve
from repro.sparse.numeric import (cholesky_solve, skyline_cholesky,
                                  skyline_solve, sparse_cholesky)


def _solve_ref(m, b):
    return np.linalg.solve(m.to_dense(), b)


def test_simplicial_cholesky(small_suite, rng):
    for m in small_suite:
        b = rng.standard_normal(m.n)
        x = cholesky_solve(sparse_cholesky(m), b)
        np.testing.assert_allclose(x, _solve_ref(m, b), rtol=1e-8, atol=1e-8)


def test_skyline_cholesky(small_suite, rng):
    for m in small_suite[:3]:
        b = rng.standard_normal(m.n)
        x = skyline_solve(skyline_cholesky(m), b)
        np.testing.assert_allclose(x, _solve_ref(m, b), rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("relax", [0, 8])
def test_multifrontal(small_suite, rng, relax):
    for m in small_suite:
        b = rng.standard_normal(m.n)
        f = multifrontal_cholesky(m, relax=relax)
        x = multifrontal_solve(f, b)
        np.testing.assert_allclose(x, _solve_ref(m, b), rtol=1e-8, atol=1e-8)


def test_multifrontal_pallas_backend(rng):
    """Dense-front math through the Pallas kernels (interpret mode)."""
    from repro.sparse.dataset import grid2d
    m = grid2d(8, 8, "g8")
    b = rng.standard_normal(m.n)
    f = multifrontal_cholesky(m, backend="pallas")
    x = multifrontal_solve(f, b)
    np.testing.assert_allclose(x, _solve_ref(m, b), rtol=1e-4, atol=1e-4)


def test_multifrontal_batched_backend(rng):
    """Level-scheduled batched factorization, one device call per bucket."""
    from repro.sparse.dataset import grid2d
    m = grid2d(10, 10, "g10")
    b = rng.standard_normal(m.n)
    f = multifrontal_cholesky(m, backend="batched")
    x = multifrontal_solve(f, b)
    np.testing.assert_allclose(x, _solve_ref(m, b), rtol=1e-4, atol=1e-4)
    assert f.schedule is not None and f.stats["nbatches"] >= 1
