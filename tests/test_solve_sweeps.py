"""Device-resident triangular sweeps: the tri-solve kernel, multi-RHS
solve parity across sweep modes, on-device refinement edge cases, the
sweep knobs through execute_plan / EngineConfig, and the extended
SolvePolicy persistence."""
import dataclasses
import json
import os

import numpy as np
import pytest
import scipy.linalg

from repro.sparse.csr import make_spd
from repro.sparse.dataset import block_arrow, grid2d, scalefree
from repro.sparse.multifrontal import (multifrontal_cholesky,
                                       multifrontal_solve)
from repro.sparse.symbolic import symbolic_cholesky


@pytest.fixture(scope="module")
def spd_grid():
    return make_spd(grid2d(12, 12, "g12"))


@pytest.fixture(scope="module")
def factored(spd_grid):
    return multifrontal_cholesky(spd_grid, backend="pipelined")


# -- batched triangular-solve kernel ------------------------------------------

@pytest.mark.parametrize("lower", [True, False])
def test_tri_solve_batch_matches_scipy(lower):
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    B, P, K = 3, 16, 5
    l = np.tril(rng.standard_normal((B, P, P))).astype(np.float32)
    l += 4 * np.eye(P, dtype=np.float32)  # well-conditioned
    x = rng.standard_normal((B, P, K)).astype(np.float32)
    got = np.asarray(ops.tri_solve_batch(l, x, lower=lower))
    for i in range(B):
        ref = scipy.linalg.solve_triangular(
            l[i] if lower else l[i].T, x[i], lower=lower)
        np.testing.assert_allclose(got[i], ref, rtol=1e-4, atol=1e-4)


def test_tri_solve_batch_rhs_tile_padding():
    from repro.kernels import ops

    rng = np.random.default_rng(1)
    l = np.tril(rng.standard_normal((2, 8, 8))).astype(np.float32)
    l += 4 * np.eye(8, dtype=np.float32)
    x = rng.standard_normal((2, 8, 3)).astype(np.float32)  # 3 % rt != 0
    base = np.asarray(ops.tri_solve_batch(l, x))
    tiled = np.asarray(ops.tri_solve_batch(l, x, rt=2))
    assert tiled.shape == x.shape
    np.testing.assert_allclose(tiled, base, rtol=1e-5, atol=1e-6)


# -- sweep-mode parity (single and multi-RHS) ---------------------------------

@pytest.mark.parametrize("k", [1, 3, 8])
def test_device_sweeps_match_sequential_multi_rhs(factored, spd_grid, k):
    rng = np.random.default_rng(2)
    B = rng.standard_normal((spd_grid.n, k))
    xs = multifrontal_solve(factored, B, mode="seq")
    xd = multifrontal_solve(factored, B, mode="device")
    assert xd.shape == B.shape
    # f32 sweeps against the fp64 reference
    np.testing.assert_allclose(xd, xs, rtol=5e-4, atol=5e-5)


def test_level_sweeps_match_sequential_multi_rhs(factored, spd_grid):
    rng = np.random.default_rng(3)
    B = rng.standard_normal((spd_grid.n, 4))
    xs = multifrontal_solve(factored, B, mode="seq")
    xl = multifrontal_solve(factored, B, mode="level")
    np.testing.assert_allclose(xl, xs, rtol=1e-12, atol=1e-12)


def test_multi_rhs_columns_match_single_solves(factored, spd_grid):
    rng = np.random.default_rng(4)
    B = rng.standard_normal((spd_grid.n, 3))
    X = multifrontal_solve(factored, B, mode="device")
    for j in range(3):
        xj = multifrontal_solve(factored, B[:, j], mode="device")
        assert xj.ndim == 1
        np.testing.assert_allclose(X[:, j], xj, rtol=1e-5, atol=1e-6)


def test_device_sweeps_on_host_factor(spd_grid):
    # a numpy-backend (fp64 host) factor uploads its sweeps on first use
    f = multifrontal_cholesky(spd_grid, backend="numpy")
    rng = np.random.default_rng(5)
    b = rng.standard_normal(spd_grid.n)
    xs = multifrontal_solve(f, b, mode="seq")
    xd = multifrontal_solve(f, b, mode="device")
    np.testing.assert_allclose(xd, xs, rtol=5e-4, atol=5e-5)


def test_device_sweep_knobs_change_nothing_numerically(factored, spd_grid):
    rng = np.random.default_rng(6)
    B = rng.standard_normal((spd_grid.n, 5))
    base = multifrontal_solve(factored, B, mode="device")
    knobbed = multifrontal_solve(factored, B, mode="device",
                                 sweep_bs=8, rt=2)
    np.testing.assert_allclose(knobbed, base, rtol=1e-5, atol=1e-6)


# -- device-resident refinement -----------------------------------------------

def test_refine_device_reaches_fp64_floor(factored, spd_grid):
    from repro.sparse.refine import refine_solve_device

    rng = np.random.default_rng(7)
    b = rng.standard_normal(spd_grid.n)
    x, info = refine_solve_device(spd_grid, factored, b)
    resid = (np.linalg.norm(spd_grid.matvec(x) - b)
             / np.linalg.norm(b))
    assert info.converged
    assert resid < 1e-10
    assert info.t_sweep >= 0.0 and info.t_residual >= 0.0


def test_refine_device_multi_rhs(factored, spd_grid):
    from repro.sparse.refine import refine_solve_device

    rng = np.random.default_rng(8)
    B = rng.standard_normal((spd_grid.n, 4))
    X, info = refine_solve_device(spd_grid, factored, B)
    assert X.shape == B.shape
    assert info.converged
    resid = np.linalg.norm(spd_grid.matvec(X) - B) / np.linalg.norm(B)
    assert resid < 1e-10


def test_refine_device_zero_rhs(factored, spd_grid):
    from repro.sparse.refine import refine_solve_device

    x, info = refine_solve_device(spd_grid, factored,
                                  np.zeros(spd_grid.n))
    assert not x.any()
    assert info.converged and info.iterations == 0


def test_refine_device_max_iter_zero_stops_unconverged(factored, spd_grid):
    from repro.sparse.refine import refine_solve_device

    b = np.random.default_rng(9).standard_normal(spd_grid.n)
    x, info = refine_solve_device(spd_grid, factored, b, max_iter=0)
    assert info.iterations == 0
    assert not info.converged
    # still returns the raw f32 solve, good to the f32 floor
    resid = np.linalg.norm(spd_grid.matvec(x) - b) / np.linalg.norm(b)
    assert resid < 1e-5


def test_refine_device_stall_guard_ends_loop(factored, spd_grid):
    from repro.sparse.refine import refine_solve_device

    # tol=0 is unreachable: once the residual bottoms out at the fp64
    # floor the stall guard must end the loop, not cycle to max_iter
    b = np.random.default_rng(14).standard_normal(spd_grid.n)
    x, info = refine_solve_device(spd_grid, factored, b,
                                  tol=0.0, max_iter=50)
    assert not info.converged
    assert info.iterations < 50
    assert info.final_residual < 1e-10  # stalled at the floor, not broken


# -- execute_plan / engine plumbing -------------------------------------------

@pytest.mark.parametrize("solve_dtype", ["fp64", "fp32", "fp32_refine"])
def test_execute_plan_device_sweep(spd_grid, solve_dtype):
    from repro.core.plan import PlanBuilder, execute_plan

    plan = PlanBuilder().build(spd_grid, algorithm="rcm")
    b = np.random.default_rng(10).standard_normal(spd_grid.n)
    r = execute_plan(spd_grid, plan, b, backend="pipelined",
                     solve_dtype=solve_dtype, sweep="device")
    assert r["sweep"] == "device"
    assert plan.meta["solve_sweep"] == "device"
    if solve_dtype == "fp32":
        assert r["solve_dtype"] == "fp32"
        assert r["residual"] < 1e-4
    else:
        # fp64 promotes to fp32_refine on the f32 device sweeps
        assert r["solve_dtype"] == "fp32_refine"
        assert r["residual"] < 1e-10
        assert r["refine_iterations"] is not None


def test_execute_plan_multi_rhs(spd_grid):
    from repro.core.plan import PlanBuilder, execute_plan

    plan = PlanBuilder().build(spd_grid, algorithm="rcm")
    B = np.random.default_rng(11).standard_normal((spd_grid.n, 4))
    r = execute_plan(spd_grid, plan, B, backend="pipelined",
                     solve_dtype="fp32_refine", sweep="device")
    assert r["x"].shape == B.shape
    assert r["residual"] < 1e-10


def test_execute_plan_rejects_bad_sweep(spd_grid):
    from repro.core.plan import PlanBuilder, execute_plan

    plan = PlanBuilder().build(spd_grid, algorithm="rcm")
    with pytest.raises(ValueError, match="sweep"):
        execute_plan(spd_grid, plan, sweep="bogus")


def test_execute_plan_sweep_metrics(spd_grid):
    from repro.core.metrics import MetricsRegistry
    from repro.core.plan import PlanBuilder, execute_plan

    plan = PlanBuilder().build(spd_grid, algorithm="rcm")
    m = MetricsRegistry()
    execute_plan(spd_grid, plan, backend="pipelined",
                 solve_dtype="fp32_refine", sweep="device", metrics=m)
    snap = m.snapshot()
    assert snap.get("solve.sweep.device") == 1
    assert snap.get("solve.refine_iterations.count") == 1
    assert any(k.startswith("solve.refine_iters.") for k in snap)
    assert "stage.solve.refine.count" in snap


def test_engine_config_sweep_validation():
    from repro.engine.config import EngineConfig

    with pytest.raises(ValueError, match="sweep"):
        EngineConfig(sweep="bogus")
    with pytest.warns(UserWarning, match="fp32_refine"):
        EngineConfig(backend="numpy", solve_dtype="fp64", sweep="device")


def test_engine_threads_sweep_knobs_into_solve_kwargs(tmp_path):
    from repro.autotune.solve_tuner import SolvePolicy, save_policy
    from repro.engine import EngineConfig, SolverEngine

    pol = SolvePolicy(bs=32, pad="pow2", backend="pipelined",
                      source="tuned", sweep_bs=16, rt=8)
    import repro.autotune.solve_tuner as st

    save_policy(dataclasses.replace(pol, device_kind=st.device_kind()),
                str(tmp_path / "tune"))
    cfg = EngineConfig(cache_dir=str(tmp_path / "cache"),
                       backend="pipelined", solve_dtype="fp32_refine",
                       sweep="device", autotune_dir=str(tmp_path / "tune"))
    kw = SolverEngine(cfg)._solve_kwargs()
    assert kw["sweep"] == "device"
    assert kw["sweep_bs"] == 16 and kw["rt"] == 8


# -- SolvePolicy persistence --------------------------------------------------

def test_solve_policy_sweep_fields_round_trip(tmp_path):
    from repro.autotune.solve_tuner import (SolvePolicy, load_policy,
                                            save_policy)

    pol = SolvePolicy(bs=32, pad="pow2", device_kind="cpu",
                      backend="pipelined", warm_factor_s=0.1,
                      source="tuned", sweep_bs=16, rt=8,
                      warm_sweep_s=0.02)
    save_policy(pol, str(tmp_path))
    back = load_policy(str(tmp_path), "cpu", backend="pipelined")
    assert back.sweep_bs == 16 and back.rt == 8
    assert back.warm_sweep_s == pytest.approx(0.02)
    assert back.source == "cached"


def test_solve_policy_pre_sweep_records_still_load(tmp_path):
    from repro.autotune.solve_tuner import (SolvePolicy, load_policy,
                                            policy_path, save_policy)

    save_policy(SolvePolicy(bs=16, pad="mult8", device_kind="cpu",
                            backend="pipelined", source="tuned"),
                str(tmp_path))
    path = policy_path(str(tmp_path), "cpu")
    with open(path) as fh:
        doc = json.load(fh)
    for key in ("sweep_bs", "rt", "warm_sweep_s"):
        doc.pop(key)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    back = load_policy(str(tmp_path), "cpu", backend="pipelined")
    assert back is not None
    assert back.sweep_bs is None and back.rt is None
    assert back.bs == 16 and back.pad == "mult8"


# -- bell SpMV multi-RHS ------------------------------------------------------

def test_bell_spmv_multi_rhs_matches_csr(spd_grid):
    from repro.kernels.ops import _interpret
    from repro.kernels.spmv_bell import bell_spmv, csr_to_bell

    rng = np.random.default_rng(13)
    n = spd_grid.n
    blocks, idx, npad = csr_to_bell(spd_grid.indptr, spd_grid.indices,
                                    spd_grid.data, n)
    X = rng.standard_normal((npad, 3)).astype(np.float32)
    X[n:] = 0.0
    got = np.asarray(bell_spmv(blocks.astype(np.float32), idx, X,
                               interpret=_interpret()))
    assert got.shape == (npad, 3)
    ref = spd_grid.matvec(X[:n].astype(np.float64))
    np.testing.assert_allclose(got[:n], ref, rtol=1e-4, atol=1e-4)
