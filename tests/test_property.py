"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.features import FEATURE_NAMES, extract_features
from repro.core.scaling import MinMaxScaler, StandardScaler
from repro.distributed.gradient_compression import _quantize
from repro.sparse.csr import (bandwidth, coo_to_csr, make_spd,
                              permute_symmetric)
from repro.sparse.reorder import LABEL_ALGORITHMS, get_reordering
from repro.sparse.symbolic import column_counts, etree, fill_in


@st.composite
def random_csr(draw, max_n=40):
    n = draw(st.integers(4, max_n))
    density = draw(st.floats(0.05, 0.3))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < density
    rows, cols = np.nonzero(mask)
    a = coo_to_csr(rows, cols, rng.standard_normal(rows.size), (n, n))
    return make_spd(a)


@given(random_csr())
@settings(max_examples=25, deadline=None)
def test_spd_and_solvable(m):
    d = m.to_dense()
    np.testing.assert_allclose(d, d.T)
    np.linalg.cholesky(d)  # SPD by construction


@given(random_csr(), st.sampled_from(LABEL_ALGORITHMS))
@settings(max_examples=20, deadline=None)
def test_reorderings_are_permutations(m, alg):
    perm = get_reordering(alg)(m)
    assert np.array_equal(np.sort(perm), np.arange(m.n))


@given(random_csr())
@settings(max_examples=15, deadline=None)
def test_fill_in_nonnegative_and_counts_bounded(m):
    assert fill_in(m) >= 0
    counts = column_counts(m)
    assert (counts >= 1).all()
    assert (counts <= m.n).all()


@given(random_csr())
@settings(max_examples=15, deadline=None)
def test_permutation_preserves_nnz_and_spd(m):
    rng = np.random.default_rng(0)
    perm = rng.permutation(m.n)
    mp = permute_symmetric(m, perm)
    assert mp.nnz == m.nnz
    assert bandwidth(mp) <= m.n - 1
    f0 = extract_features(m)
    f1 = extract_features(mp)
    i = FEATURE_NAMES.index("nnz")
    assert f0[i] == f1[i]


@given(random_csr())
@settings(max_examples=10, deadline=None)
def test_etree_is_forest(m):
    parent = etree(m)
    # following parents always terminates (parents strictly increase)
    for v in range(m.n):
        steps = 0
        while parent[v] != -1:
            v = int(parent[v])
            steps += 1
            assert steps <= m.n


@given(st.lists(st.floats(-1e4, 1e4), min_size=8, max_size=200))
@settings(max_examples=30, deadline=None)
def test_scalers_roundtrip_ranges(vals):
    x = np.array(vals, dtype=np.float64).reshape(-1, 2) \
        if len(vals) % 2 == 0 else np.array(vals[:-1]).reshape(-1, 2)
    if x.shape[0] < 2:
        return
    mm = MinMaxScaler().fit(x)
    t = mm.transform(x)
    assert t.min() >= -1e-9 and t.max() <= 1 + 1e-9
    ss = StandardScaler().fit(x)
    t2 = ss.transform(x)
    assert abs(t2.mean()) < 1e-6


@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
@settings(max_examples=30, deadline=None)
def test_int8_quantization_error_bound(seed, scale):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64) * scale, jnp.float32)
    q, s = _quantize(g)
    err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(g))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ULP of the int8 grid
